"""Probe-backend dispatch layer (ISSUE 1 tentpole).

Covers:
  * range/span search parity: Pallas masked-compare kernel vs searchsorted,
  * power-of-two capacity quantization invariants,
  * end-to-end engine parity: both backends produce bit-identical relations
    and communication accounting over a synthetic adaptive workload,
  * recompilation regression: repeated same-shape queries after warmup do
    not grow the jit compile cache (the capacity classes do their job).
"""
from __future__ import annotations

import numpy as np
import pytest

import repro.core  # noqa: F401  (x64 on, as in production)
import jax.numpy as jnp

from repro.core import backend as be
from repro.core.engine import AdHashEngine
from repro.data.synthetic_rdf import Workload, lubm_like

from reference import match_query

BACKENDS = ("searchsorted", "pallas")


# ------------------------------------------------------------ search parity
@pytest.mark.parametrize("dtype", [jnp.int32, jnp.int64])
@pytest.mark.parametrize("n,m", [(57, 9), (600, 130)])
def test_range_search_backends_agree(dtype, n, m):
    rng = np.random.default_rng(0)
    info = np.iinfo(np.int32 if dtype == jnp.int32 else np.int64)
    keys = np.sort(rng.integers(0, 4 * n, n))
    keys = np.concatenate([keys, [info.max] * 7])  # store-style max padding
    probes = rng.integers(-3, 4 * n + 3, m)
    keys_j = jnp.asarray(keys, dtype)
    probes_j = jnp.asarray(probes, dtype)
    lo_s, hi_s = be.range_search(keys_j, probes_j, backend="searchsorted")
    lo_p, hi_p = be.range_search(keys_j, probes_j, backend="pallas")
    np.testing.assert_array_equal(np.asarray(lo_s), np.asarray(lo_p))
    np.testing.assert_array_equal(np.asarray(hi_s), np.asarray(hi_p))


def test_span_search_backends_agree():
    rng = np.random.default_rng(1)
    keys = jnp.asarray(np.sort(rng.integers(0, 1000, 300)), jnp.int64)
    lo_keys = jnp.asarray(rng.integers(0, 1000, 40), jnp.int64)
    hi_keys = lo_keys + jnp.asarray(rng.integers(0, 50, 40), jnp.int64)
    out_s = be.span_search(keys, lo_keys, hi_keys, backend="searchsorted")
    out_p = be.span_search(keys, lo_keys, hi_keys, backend="pallas")
    for a, b in zip(out_s, out_p):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resolve_backend():
    assert be.resolve_backend("auto") in be.PROBE_BACKENDS
    assert be.resolve_backend(None) in be.PROBE_BACKENDS
    assert be.resolve_backend("pallas") == "pallas"
    with pytest.raises(ValueError):
        be.resolve_backend("quantum")


# ------------------------------------------------------------- quantization
def test_quantize_capacity_classes():
    for n in (0, 1, 63, 64, 65, 100, 4095, 4096, 4097):
        q = be.quantize_capacity(n)
        assert q >= max(n, 64)
        assert q & (q - 1) == 0, q  # power of two
    # monotone, and idempotent on its own output
    qs = [be.quantize_capacity(n) for n in range(1, 3000, 17)]
    assert qs == sorted(qs)
    assert all(be.quantize_capacity(q) == q for q in qs)
    # ceil caps hints
    assert be.quantize_capacity(1 << 30, ceil=1 << 20) == 1 << 20


# --------------------------------------------------------- end-to-end parity
def _workload():
    d, triples = lubm_like(n_universities=2, depts_per_univ=2,
                           profs_per_dept=2, students_per_prof=2)
    wl = Workload(d, seed=7)
    qs = wl.sample(4)
    return triples, qs + qs  # repeats drive the heat map over the threshold


def test_engine_backend_parity():
    """Both probe backends: bit-identical relations and comm accounting,
    across distributed, parallel and (post-IRD) parallel-replica modes."""
    triples, qs = _workload()
    runs = {}
    for backend in BACKENDS:
        eng = AdHashEngine(triples, 3, adaptive=True, frequency_threshold=2,
                           capacity=256, probe_backend=backend)
        assert eng.probe_backend == backend
        runs[backend] = [
            (rel.to_set(), st.comm_cells, st.mode)
            for rel, st in (eng.query(q) for q in qs)
        ]
    assert any(mode == "parallel-replica" for _, _, mode in
               runs["searchsorted"]), "workload never adapted"
    for (rel_a, comm_a, mode_a), (rel_b, comm_b, mode_b) in zip(
        runs["searchsorted"], runs["pallas"]
    ):
        assert rel_a == rel_b
        assert comm_a == comm_b
        assert mode_a == mode_b


def test_engine_backend_parity_vs_oracle():
    """Each backend independently agrees with the brute-force oracle."""
    triples, qs = _workload()
    for backend in BACKENDS:
        eng = AdHashEngine(triples, 2, adaptive=False, capacity=256,
                           probe_backend=backend)
        for q in qs[:4]:
            rel, _ = eng.query(q)
            got = set(map(tuple, rel.project_to(q.vars)))
            assert got == match_query(triples, q), (backend, q.name)


# --------------------------------------------------- recompilation regression
def test_repeated_queries_do_not_recompile():
    """After warmup, same-template queries (fresh constants) hit the jit
    cache: zero new compilations (capacity quantization works)."""
    d, triples = lubm_like()
    wl = Workload(d, seed=11)
    eng = AdHashEngine(triples, 4, adaptive=False)
    # warm every template once (shapes are per-template, not per-constant)
    warm = [t.instantiate(wl.rng) for t in wl.templates.values()]
    for q in warm:
        eng.query(q)
    baseline = be.probe_compile_cache_size()
    fresh = [t.instantiate(wl.rng) for t in wl.templates.values()]
    for q in warm + fresh:  # exact repeats + fresh constants
        eng.query(q)
    assert be.probe_compile_cache_size() == baseline


def _mixed_batch_workload(wl, n_per_template=3):
    """Mixed workload with >=2 instances per template (real batch buckets)."""
    return [
        t.instantiate(wl.rng)
        for t in wl.templates.values()
        for _ in range(n_per_template)
    ]


def test_batched_queries_do_not_recompile():
    """ISSUE 2: a warmed mixed workload executed via ``query_batch`` triggers
    zero new jit compilations — batch-size quantization keeps the leading
    batch axis, and capacity classes keep the stage shapes, cache-stable."""
    d, triples = lubm_like()
    wl = Workload(d, seed=13)
    eng = AdHashEngine(triples, 4, adaptive=False)
    eng.query_batch(_mixed_batch_workload(wl))  # warm the batched pipelines
    baseline = be.probe_compile_cache_size()
    # fresh constants, same templates; also a different (but same-class
    # after power-of-two padding) number of instances per template
    eng.query_batch(_mixed_batch_workload(wl))
    eng.query_batch(_mixed_batch_workload(wl, n_per_template=4))
    assert be.probe_compile_cache_size() == baseline


def test_sharded_queries_do_not_recompile():
    """ISSUE 4: the mesh-substrate stage wrappers obey the same capacity
    discipline — a warmed sharded workload (sequential *and* batched paths)
    triggers zero new jit compilations.  Tier-1 runs this on a one-device
    mesh; the 8-device subprocess suite re-checks it with real sharding."""
    from repro.core.substrate import MeshSubstrate

    d, triples = lubm_like()
    wl = Workload(d, seed=19)
    eng = AdHashEngine(triples, 4, adaptive=False, substrate=MeshSubstrate())
    warm = [t.instantiate(wl.rng) for t in wl.templates.values()]
    for q in warm:
        eng.query(q)
    eng.query_batch(_mixed_batch_workload(wl))
    baseline = be.probe_compile_cache_size()
    fresh = [t.instantiate(wl.rng) for t in wl.templates.values()]
    for q in warm + fresh:
        eng.query(q)
    eng.query_batch(_mixed_batch_workload(wl))
    eng.query_batch(_mixed_batch_workload(wl, n_per_template=4))
    assert be.probe_compile_cache_size() == baseline


def test_sharded_retry_doubling_stays_power_of_two_classes():
    """Overflow retries under a mesh substrate must double into power-of-two
    capacity classes — per-shard buffer shapes are static jit shapes, so a
    non-class capacity would recompile every sharded stage.  Warm with a
    deliberately undersized capacity (forcing retry doubling), then re-run:
    the jit cache must not grow."""
    from repro.core.substrate import MeshSubstrate

    d, triples = lubm_like()
    wl = Workload(d, seed=23)
    eng = AdHashEngine(triples, 4, adaptive=False, capacity=64,
                       substrate=MeshSubstrate())
    warm = [t.instantiate(wl.rng) for t in wl.templates.values()]

    def run_all():
        retries = 0
        for q in warm:
            # bypass the planner's capacity hint: the deliberately tiny
            # capacity must overflow and walk up the class ladder
            plan = eng.planner.plan(q)
            _, st = eng.executor.execute(q, plan.ordering, plan.join_vars,
                                         capacity=64)
            retries += st.n_retries
        return retries

    assert run_all() > 0  # the tiny capacity actually forced doubling
    baseline = be.probe_compile_cache_size()
    assert run_all() > 0  # same overflows again ...
    assert be.probe_compile_cache_size() == baseline  # ... same classes


def test_batched_capacity_classes_compile_once_each():
    """Buckets with distinct capacity classes compile at most once each:
    the classes split into distinct buckets, and re-running the same
    two-class workload adds nothing to the jit cache."""
    from repro.core.batcher import WorkloadBatcher

    d, triples = lubm_like()
    wl = Workload(d, seed=17)
    eng = AdHashEngine(triples, 4, adaptive=False)
    t_q1 = wl.templates["q1"]

    def run_two_classes():
        batcher = WorkloadBatcher()
        for i in range(4):
            q = t_q1.instantiate(wl.rng)
            plan = eng.planner.plan(q)
            batcher.add(i, q, plan.ordering, plan.join_vars,
                        4096 if i % 2 == 0 else 1 << 14)
        buckets = batcher.buckets()
        assert len(buckets) == 2  # same structure, two capacity classes
        for b in buckets:
            eng.executor.execute_batch(b.plan, b.stacked_consts())

    run_two_classes()
    baseline = be.probe_compile_cache_size()
    run_two_classes()
    assert be.probe_compile_cache_size() == baseline
