"""Per-arch smoke tests: reduced config, one forward/train step + one decode
step on CPU; asserts output shapes and no NaNs (deliverable f)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models.model_zoo import build_model


def _batch_for(model, b=2, t=16):
    cfg = model.cfg
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (b, t)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.vlm.n_patches, cfg.vlm.d_vision)),
            jnp.float32,
        )
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encdec.n_frames, cfg.d_model)),
            jnp.float32,
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch_for(model)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss={loss}"
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves
    for g in leaves:
        assert bool(jnp.all(jnp.isfinite(g))), f"{arch}: non-finite grad"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_smoke(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    b, max_len = 2, 32
    cache = model.init_cache(b, max_len)
    batch = {
        "tokens": jnp.zeros((b, 1), jnp.int32),
        "pos": jnp.int32(3),
    }
    if cfg.family == "audio":
        import numpy as np
        from repro.models.whisper import whisper_encode

        frames = jnp.asarray(
            np.random.default_rng(0).normal(
                size=(b, cfg.encdec.n_frames, cfg.d_model)
            ),
            jnp.float32,
        )
        batch["enc"] = whisper_encode(params, frames, cfg)
    logits, new_cache = model.decode(params, cache, batch)
    assert logits.shape == (b, 1, cfg.vocab_size), f"{arch}: {logits.shape}"
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch
    # cache structure preserved
    jax.tree_util.tree_structure(cache) == jax.tree_util.tree_structure(
        new_cache
    )
