"""End-to-end behaviour tests for the whole system (deliverable c).

The full paper loop on a realistic synthetic graph: bootstrap -> distributed
queries -> heat map -> IRD -> parallel mode -> eviction -> recovery, plus
the LM-side end-to-end train step under the local mesh.
"""
from __future__ import annotations

import numpy as np
import pytest

import repro.core  # noqa: F401
import jax
import jax.numpy as jnp

from repro.core.engine import AdHashEngine
from repro.data.synthetic_rdf import Workload, lubm_like

from reference import match_query


@pytest.fixture(scope="module")
def lubm():
    return lubm_like(n_universities=3, depts_per_univ=2, profs_per_dept=3,
                     students_per_prof=5, seed=1)


def test_full_adaptive_lifecycle(lubm):
    """The §3.4 system overview, end to end, results checked vs brute force."""
    d, triples = lubm
    eng = AdHashEngine(triples, 6, adaptive=True, frequency_threshold=3,
                       replication_budget=10_000, capacity=4096)
    wl = Workload(d, seed=2)
    seen_modes = set()
    for _ in range(4):
        for name in ("q2", "q7", "q12"):
            q = wl.templates[name].instantiate(wl.rng)
            rel, st = eng.query(q)
            seen_modes.add(st.mode)
            got = set(map(tuple, rel.project_to(q.vars)))
            assert got == match_query(triples, q), (name, st.mode)
    # the engine actually moved through both execution regimes
    assert "distributed" in seen_modes
    assert "parallel-replica" in seen_modes
    assert eng.report.n_redistributions >= 2
    # adapted queries stopped communicating
    tail_comm = [c for _, c, _ in eng.report.history[-3:]]
    assert sum(tail_comm) == 0


def test_mode_decisions_match_paper_rules(lubm):
    """Subject stars -> parallel; non-star joins -> distributed until hot."""
    d, triples = lubm
    eng = AdHashEngine(triples, 4, adaptive=False)
    wl = Workload(d, seed=5)
    star = wl.templates["q1"].instantiate(wl.rng)  # subject star
    _, st = eng.query(star)
    assert st.mode == "parallel" and st.comm_cells == 0
    cyc = wl.templates["q2"].instantiate(wl.rng)
    _, st2 = eng.query(cyc)
    assert st2.mode == "distributed"


def test_engine_survives_worker_count_change(lubm):
    """Elastic W: identical results under different worker counts."""
    d, triples = lubm
    wl = Workload(d, seed=7)
    q = wl.templates["q9"].instantiate(wl.rng)
    ref = match_query(triples, q)
    for w in (2, 5, 8):
        eng = AdHashEngine(triples, w, adaptive=False, capacity=4096)
        rel, _ = eng.query(q)
        assert set(map(tuple, rel.project_to(q.vars))) == ref, w


def test_lm_train_step_under_local_mesh():
    """LM side: jitted sharded train step improves loss (deliverable b)."""
    from repro.configs import get_smoke_config
    from repro.data.tokens import make_batch
    from repro.launch.mesh import make_local_mesh
    from repro.launch.shardings import named, param_specs
    from repro.launch.train import make_train_step
    from repro.models.model_zoo import build_model
    from repro.optim.adamw import AdamWConfig, adamw_init

    cfg = get_smoke_config("qwen2-moe-a2.7b")
    model = build_model(cfg)
    mesh = make_local_mesh()
    params = model.init(jax.random.key(0))
    params = jax.device_put(params, named(mesh, param_specs(params, mesh)))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=5e-3)),
                   donate_argnums=(0, 1))
    batch = make_batch(cfg, 4, 32, 0)
    first = None
    for _ in range(6):
        params, opt, metrics = step(params, opt, batch)
        if first is None:
            first = float(metrics["loss"])
    assert float(metrics["loss"]) < first
