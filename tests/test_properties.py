"""Property-based tests (hypothesis) for system invariants.

Invariants checked against the brute-force oracle on random graphs/queries:
  * distributed execution is exact for any ordering the planner emits,
  * adaptivity never changes results (parallel-replica == distributed),
  * partitioning is a total assignment; subject-locality holds,
  * relational primitives: expand/compact/unique are exact vs numpy.
"""
from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional test dependency "
                    "(pip install hypothesis / the 'test' extra)")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

import repro.core  # noqa: F401
import jax.numpy as jnp

from repro.core.engine import AdHashEngine
from repro.core.partition import hash_ids, partition_by_subject
from repro.core.query import Const, Query, TriplePattern, Var
from repro.core.relalg import bucket_by_dest, compact, expand, unique_compact
from repro.core import dsj

from reference import match_query

_SETTINGS = dict(
    deadline=None,
    max_examples=12,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)


@st.composite
def graph_and_query(draw):
    n_v = draw(st.integers(8, 24))
    n_p = draw(st.integers(2, 4))
    n_t = draw(st.integers(20, 120))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    triples = np.unique(
        np.stack(
            [
                rng.integers(0, n_v, n_t),
                n_v + rng.integers(0, n_p, n_t),
                rng.integers(0, n_v, n_t),
            ],
            axis=1,
        ).astype(np.int64),
        axis=0,
    )
    # connected 2-3 pattern query over variables a,b,c
    shape = draw(st.sampled_from(["chain2", "chain3", "star2", "oo2"]))
    a, b, cv, dv = Var("a"), Var("b"), Var("c"), Var("d")
    p = [Const(int(n_v + i % n_p)) for i in range(3)]
    if shape == "chain2":
        pats = [TriplePattern(a, p[0], b), TriplePattern(b, p[1], cv)]
    elif shape == "chain3":
        pats = [
            TriplePattern(a, p[0], b),
            TriplePattern(b, p[1], cv),
            TriplePattern(cv, p[2], dv),
        ]
    elif shape == "star2":
        pats = [TriplePattern(a, p[0], b), TriplePattern(a, p[1], cv)]
    else:  # object-object join
        pats = [TriplePattern(a, p[0], cv), TriplePattern(b, p[1], cv)]
    return triples, Query(pats)


@given(graph_and_query(), st.integers(1, 5))
@settings(**_SETTINGS)
def test_engine_matches_bruteforce(gq, w):
    triples, q = gq
    eng = AdHashEngine(triples, w, adaptive=False, capacity=2048)
    rel, _ = eng.query(q)
    got = set(map(tuple, rel.project_to(q.vars)))
    assert got == match_query(triples, q)


@given(graph_and_query())
@settings(**_SETTINGS)
def test_adaptivity_preserves_results(gq):
    triples, q = gq
    ref = match_query(triples, q)
    eng = AdHashEngine(triples, 3, adaptive=True, frequency_threshold=2,
                       capacity=2048)
    for _ in range(4):
        rel, _ = eng.query(q)
        assert set(map(tuple, rel.project_to(q.vars))) == ref


@given(st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=200),
       st.integers(1, 16))
@settings(**_SETTINGS)
def test_partition_total_and_local(ids, w):
    ids = np.array(ids, dtype=np.int64)
    triples = np.stack([ids, ids * 0, ids * 0], axis=1)
    assign = partition_by_subject(triples, w)
    assert assign.min() >= 0 and assign.max() < w
    # locality: same subject -> same worker
    h = hash_ids(ids) % w
    assert (assign == h).all()


@given(st.lists(st.integers(0, 50), min_size=1, max_size=64),
       st.integers(1, 64))
@settings(**_SETTINGS)
def test_expand_matches_numpy(counts, cap):
    counts = np.array(counts)
    lo = np.zeros_like(counts)
    hi = counts
    left, pos, valid, total = expand(jnp.asarray(lo), jnp.asarray(hi), cap)
    ref = [(i, j) for i, c in enumerate(counts) for j in range(c)][:cap]
    got = [
        (int(l), int(p))
        for l, p, v in zip(left, pos, valid)
        if bool(v)
    ]
    assert got == ref
    assert int(total) == counts.sum()


@given(st.lists(st.integers(-5, 5), min_size=1, max_size=64),
       st.integers(1, 64))
@settings(**_SETTINGS)
def test_unique_compact_matches_numpy(vals, cap):
    v = np.array(vals, dtype=np.int32)
    valid = v >= 0
    uniq, mask, n = unique_compact(
        jnp.asarray(v), jnp.asarray(valid), cap, 2**31 - 1
    )
    ref = np.unique(v[valid])
    got = np.asarray(uniq)[np.asarray(mask)]
    assert int(n) == len(ref)
    np.testing.assert_array_equal(got, ref[:cap])


@given(
    st.lists(st.integers(0, 2**20), min_size=1, max_size=64),
    st.integers(2, 8),
)
@settings(**_SETTINGS)
def test_bucket_by_dest_routes_everything(vals, w):
    v = np.array(vals, dtype=np.int32)
    dest = (hash_ids(v.astype(np.int64)) % w).astype(np.int32)
    send, svalid, maxw = bucket_by_dest(
        jnp.asarray(v)[:, None], jnp.asarray(dest), jnp.ones(len(v), bool),
        w, cap_peer=len(v),
    )
    send = np.asarray(send)[..., 0]
    svalid = np.asarray(svalid)
    # every value lands in exactly the bucket of its destination
    for d in range(w):
        got = sorted(send[d][svalid[d]].tolist())
        ref = sorted(v[dest == d].tolist())
        assert got == ref
    assert int(maxw) <= len(v)


@given(st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=100))
@settings(**_SETTINGS)
def test_jnp_hash_matches_numpy_hash(ids):
    a = np.array(ids, dtype=np.int64)
    np_h = hash_ids(a)
    j_h = np.asarray(dsj.jnp_hash_ids(jnp.asarray(a)))
    np.testing.assert_array_equal(np_h, j_h)
