"""Workload adaptivity demo (paper Figs 13/14): run a shifting query
workload with and without adaptivity and print the cumulative-cost curves.

Run:  PYTHONPATH=src python examples/rdf_workload.py
"""
from __future__ import annotations

import time

import repro.core  # noqa: F401
from repro.core.engine import AdHashEngine
from repro.data.synthetic_rdf import Workload, lubm_like


def run_engine(adaptive: bool, triples, d, order, per_phase=20):
    eng = AdHashEngine(triples, 8, adaptive=adaptive, frequency_threshold=4)
    wl = Workload(d, seed=3)
    cum_t, cum_c = [], []
    t_total = c_total = 0.0
    for name in order:
        for _ in range(per_phase):
            q = wl.templates[name].instantiate(wl.rng)
            t0 = time.perf_counter()
            _, st = eng.query(q)
            t_total += time.perf_counter() - t0
            c_total = (eng.report.comm_cells + eng.report.ird_comm_cells) * 4
            cum_t.append(t_total)
            cum_c.append(c_total)
    return eng, cum_t, cum_c


def sparkline(values, width=48):
    blocks = " .:-=+*#%@"
    mx = max(values) or 1
    idx = [int((len(blocks) - 1) * v / mx) for v in values]
    step = max(1, len(idx) // width)
    return "".join(blocks[i] for i in idx[::step])


def main() -> None:
    d, triples = lubm_like(n_universities=4)
    order = ["q1", "q12", "q7", "q2"]  # workload shifts every 20 queries

    na, t_na, c_na = run_engine(False, triples, d, order)
    ad, t_ad, c_ad = run_engine(True, triples, d, order)

    print("cumulative wall time (each char = 2 queries; phases shift q1->q12->q7->q2)")
    print(f"  AdHash-NA {t_na[-1]:7.2f}s |{sparkline(t_na)}|")
    print(f"  AdHash    {t_ad[-1]:7.2f}s |{sparkline(t_ad)}|")
    print("cumulative communication bytes")
    print(f"  AdHash-NA {c_na[-1]:9.0f}B |{sparkline(c_na)}|")
    print(f"  AdHash    {c_ad[-1]:9.0f}B |{sparkline(c_ad)}|")
    print(
        f"\nAdHash answered "
        f"{ad.report.n_parallel_replica + ad.report.n_parallel}"
        f"/{ad.report.n_queries} queries in parallel mode, "
        f"{ad.report.n_redistributions} IRD redistributions, "
        f"replication {ad.replication_ratio():.2f}, "
        f"{ad.report.n_evictions} evictions"
    )
    speedup = t_na[-1] / max(t_ad[-1], 1e-9)
    comm_ratio = c_na[-1] / max(c_ad[-1], 1)
    print(f"speedup {speedup:.1f}x, communication reduced {comm_ratio:.1f}x")


if __name__ == "__main__":
    main()
