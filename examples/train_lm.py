"""End-to-end training driver: train a ~130M-param model for a few hundred
steps on CPU with the full stack — sharded params, AdamW, checkpointing,
and the AdHash-style adaptive embedding controller in the loop.

Run (quick):   PYTHONPATH=src python examples/train_lm.py --steps 30
Run (full):    PYTHONPATH=src python examples/train_lm.py \
                   --arch mamba2-130m --steps 300 --batch 8 --seq 512
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.adaptive import AdaptiveShardingController
from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.tokens import synthetic_batches
from repro.launch.mesh import make_local_mesh
from repro.launch.shardings import named, param_specs
from repro.launch.train import make_train_step
from repro.models.model_zoo import build_model
from repro.optim.adamw import AdamWConfig, adamw_init


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    mesh = make_local_mesh()
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.0f}M "
          f"mesh={dict(mesh.shape)}")

    params = model.init(jax.random.key(0))
    params = jax.device_put(params, named(mesh, param_specs(params, mesh)))
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(model, AdamWConfig(lr=3e-4)),
                      donate_argnums=(0, 1))

    # the paper's controller, watching token access (Zipf -> hot rows)
    ctrl = AdaptiveShardingController(
        cfg.vocab_size,
        budget=cfg.adaptive.embedding_hot_budget if cfg.adaptive else 1024,
    )
    ckpt = CheckpointManager(args.ckpt, async_save=True)

    t0 = time.perf_counter()
    losses = []
    for step, batch in enumerate(
        synthetic_batches(cfg, args.batch, args.seq, args.steps)
    ):
        ctrl.observe(np.asarray(batch["tokens"]))
        params, opt, metrics = step_fn(params, opt, batch)
        losses.append(float(metrics["loss"]))
        if step % 10 == 0:
            plan = ctrl.replan()
            print(
                f"step {step:4d} loss {losses[-1]:.4f} "
                f"gnorm {float(metrics['grad_norm']):.2f} "
                f"hot={plan.n_hot} coverage={plan.coverage:.2f} "
                f"({time.perf_counter() - t0:.0f}s)"
            )
        if (step + 1) % 50 == 0:
            ckpt.save(params, opt, step + 1)
    ckpt.wait()
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f}); "
          f"checkpoint at step {ckpt.latest_step()}")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
