"""Serve a small *language model* with batched decode + adaptive embedding.

Demonstrates the paper's pay-as-you-go loop on the LM side: the controller
watches request token ids, replicates the hot rows, and the embedding's
cold-exchange capacity shrinks — the LM equivalent of queries flipping
from distributed to parallel mode.  For the RDF engine's own online
serving front-end (continuous batching under an SLO, admission control,
load shedding — :mod:`repro.serving`), see ``examples/serve_rdf.py``.

Run:  PYTHONPATH=src python examples/serve_adaptive.py
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.adaptive import AdaptiveShardingController
from repro.data.tokens import zipf_tokens
from repro.launch.train import make_serve_step
from repro.models.model_zoo import build_model


def main() -> None:
    cfg = get_smoke_config("qwen1.5-4b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    serve = jax.jit(make_serve_step(model), donate_argnums=(1,))

    batch_size, max_len = 8, 64
    ctrl = AdaptiveShardingController(cfg.vocab_size, budget=64,
                                      threshold=0.5)
    rng = np.random.default_rng(0)

    for round_ in range(4):
        cache = model.init_cache(batch_size, max_len)
        prompt = zipf_tokens(rng, cfg.vocab_size, (batch_size, 1))
        tok = jnp.asarray(prompt, jnp.int32)
        generated = [tok]
        t0 = time.perf_counter()
        for pos in range(12):
            ctrl.observe(np.asarray(tok))
            tok, cache = serve(
                params, cache, {"tokens": tok, "pos": jnp.int32(pos)}
            )
            tok = tok[:, None]
            generated.append(tok)
        plan = ctrl.replan()
        cold = ctrl.cold_capacity(batch_size)
        print(
            f"round {round_}: decoded {len(generated) - 1} steps x "
            f"{batch_size} streams in {time.perf_counter() - t0:.2f}s | "
            f"hot rows={plan.n_hot} coverage={plan.coverage:.2f} "
            f"cold-exchange capacity={cold}/{batch_size}"
        )
    print("adaptive plan converged; hot ids:", plan.hot_ids[:10], "...")


if __name__ == "__main__":
    main()
