"""Online RDF serving demo (ISSUE 8, DESIGN §10): continuous batching
under a latency SLO with admission control, backpressure, and shedding.

An open-loop Poisson stream of LUBM template queries is driven through
``repro.serving.ServeLoop`` on a virtual clock with a fixed per-dispatch
service model, so the run is a deterministic discrete-event simulation —
the same regime the serving test suite gates.  Two runs are shown:

  * comfortable load: everything is answered, p99 well under the SLO;
  * overload (well past saturation): the bounded queue pushes back
    (``RetryAfter``), doomed requests are shed *before* execution
    (``SheddedResult``), the brownout ladder defers adaptivity work first,
    and the admitted requests still meet the SLO.

Run:  PYTHONPATH=src python examples/serve_rdf.py
"""
from __future__ import annotations

import repro.core  # noqa: F401
from repro.core.engine import AdHashEngine
from repro.data.synthetic_rdf import Workload, lubm_like
from repro.runtime.fault_injection import VirtualClock
from repro.serving import (ServeConfig, ServeLoop, open_loop_arrivals,
                           replay_open_loop)

SVC_S = 0.02           # modeled seconds per dispatched bucket
BATCH = 4              # continuous-batching target -> saturation 200 qps
SLO_S = 0.2


def run(rate_qps: float, label: str, n: int = 200) -> None:
    d, triples = lubm_like(n_universities=2, depts_per_univ=2,
                           profs_per_dept=2, students_per_prof=2)
    eng = AdHashEngine(triples, 8, adaptive=True, frequency_threshold=2,
                       capacity=256)
    loop = ServeLoop(
        eng,
        ServeConfig(slo_s=SLO_S, batch_target=BATCH, queue_bound=16,
                    bucket_window=16),
        clock=VirtualClock(), service_model=lambda _: SVC_S)
    qs = Workload(d, seed=5).sample(n)
    arrivals = open_loop_arrivals(qs, rate_qps=rate_qps, seed=5)
    replay_open_loop(loop, arrivals)

    r = loop.report
    print(f"\noffered {rate_qps:.0f} qps ({label}), "
          f"SLO {SLO_S * 1e3:.0f}ms:")
    print(f"  answered {r.answered}/{r.offered}  "
          f"(p50 {r.p50_s * 1e3:.0f}ms, p99 {r.p99_s * 1e3:.0f}ms, "
          f"late {r.late})")
    print(f"  shed {r.shed} ({r.shed_rate:.0%} of admitted)  "
          f"rejected {r.rejected} "
          f"(queue_full={r.rejected_queue_full} "
          f"brownout={r.rejected_brownout})")
    print(f"  brownout level changes: {len(r.brownout_events)}, "
          f"adaptivity deferrals: {r.adaptivity_deferrals}")
    print(f"  engine: {eng.report.n_queries} queries, "
          f"{eng.report.n_redistributions} IRD redistributions")


def main() -> None:
    print("deterministic serving DES: virtual clock, "
          f"{SVC_S * 1e3:.0f}ms/bucket, batch target {BATCH} "
          f"(upper-bound saturation {BATCH / SVC_S:.0f} qps; the mixed "
          "workload fragments shape buckets, so effective saturation is "
          "lower)")
    run(rate_qps=30.0, label="comfortable")  # everything answered in time
    run(rate_qps=400.0, label="overload")    # backpressure + shedding engage


if __name__ == "__main__":
    main()
