"""Quickstart: the AdHash engine end to end in ~60 lines.

Loads a synthetic LUBM-like RDF graph, runs a query in distributed mode,
lets the engine adapt (heat map -> IRD -> pattern index), and shows the same
query answered in parallel mode with zero communication.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from __future__ import annotations

import repro.core  # noqa: F401  (enables x64 for composite keys)
from repro.core.engine import AdHashEngine
from repro.data.synthetic_rdf import Workload, lubm_like


def main() -> None:
    # 1. generate + bulk-load data (subject-hash partitioning, ~1 second —
    #    the paper's "low startup" claim is the whole point)
    dictionary, triples = lubm_like(n_universities=4)
    engine = AdHashEngine(
        triples,
        n_workers=8,
        dictionary=dictionary,
        adaptive=True,
        frequency_threshold=3,
        replication_budget=5_000,
    )
    print(f"loaded {len(triples)} triples on {engine.w} workers "
          f"in {engine.startup_time_s:.2f}s")

    # 2. a cyclic query (students sharing their alma mater with the dept's
    #    university) — needs communication under plain hash partitioning
    workload = Workload(dictionary, mix={"q2": 1.0}, seed=0)
    for i in range(6):
        query = workload.sample(1)[0]
        rel, stats = engine.query(query)
        n = len(rel.to_numpy())
        print(
            f"query {i}: mode={stats.mode:17s} results={n:4d} "
            f"comm={stats.comm_bytes:8d}B plan={stats.plan[:2]}"
        )

    # 3. after the frequency threshold the pattern was redistributed:
    rep = engine.report
    print(
        f"\nredistributions={rep.n_redistributions} "
        f"replication_ratio={engine.replication_ratio():.3f} "
        f"parallel_queries={rep.n_parallel_replica}/{rep.n_queries}"
    )
    print("load balance:", engine.load_balance())

    # 4. decode a few result rows back to strings
    rel, _ = engine.query(workload.sample(1)[0])
    rows = rel.to_numpy()[:5]
    for row in rows:
        print("  ", [dictionary.decode_term(v) for v in row])


if __name__ == "__main__":
    main()
